"""repro.analysis — mechanical enforcement of the repo's invariants.

Two layers (DESIGN.md §Static analysis):

  * AST rule engine (`engine`, `rules`, `cli`): repo-specific lint,
    `python -m repro.analysis src/`, suppressible per line via a
    ``repro: noqa[RULE]: reason`` comment.
  * Jaxpr/HLO contract checker (`contracts`): `assert_plan_contracts(plan)`
    abstractly traces any ExecutionPlan's solve and asserts the traffic /
    tracing / donation contracts the roofline model prices.

The lint side is stdlib-only; `contracts` is imported lazily so the lint
gate never pays (or requires) a jax import.
"""
from repro.analysis.engine import (  # noqa: F401
    Finding, LintReport, lint_paths, lint_source,
)
from repro.analysis.rules import RULES  # noqa: F401


def assert_plan_contracts(plan, **kwargs):
    """Lazy forwarder to `repro.analysis.contracts.assert_plan_contracts`."""
    from repro.analysis import contracts

    return contracts.assert_plan_contracts(plan, **kwargs)


__all__ = ["Finding", "LintReport", "lint_paths", "lint_source", "RULES",
           "assert_plan_contracts"]
