"""AST rule engine: file walking, module model, suppression, reachability.

Layer 1 of `repro.analysis` (see DESIGN.md §Static analysis).  The engine is
deliberately stdlib-only (ast + re): the lint gate must run in CI before any
jax import and in well under a second, so rules operate on syntax plus two
cheap whole-program facts the engine precomputes:

  * the repo-relative module name of every file (``src/repro/core/qr.py`` ->
    ``repro.core.qr``), so rules can reason about layering;
  * the set of modules REACHABLE from the service workers
    (`repro.serve.decomp.service` et al.) through imports at any depth —
    module-level AND function-level (the lazy-import convention means the
    import graph at the top level alone would miss most of the hot path).

Suppression policy: one finding, one line, one stated reason —

    _table = {}  <hash> repro: noqa[RL002]: guarded by _lock (see record/lookup)

(with ``<hash>`` the comment character).  A ``repro: noqa[RULE]`` comment
without a reason does NOT suppress (the point of
the ledger is the reasons); ``RULE`` may be the id (``RL002``), the name
(``mutable-global``), or ``all``.  Suppressions that match no finding are
reported by the CLI in verbose mode so dead noqa comments rot visibly.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: where service worker threads enter library code — the reachability roots
#: for the shared-mutable-state rule (RL002).
SERVICE_ROOTS: Tuple[str, ...] = (
    "repro.serve.decomp.service",
    "repro.serve.decomp.scheduler",
)

NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[(?P<rules>[A-Za-z0-9_\-, ]+)\]\s*(?::\s*(?P<reason>\S.*))?"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one line."""

    rule: str      # "RL002"
    name: str      # "mutable-global"
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}[{self.name}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    rules: Tuple[str, ...]
    reason: str
    line: int

    def covers(self, finding: Finding) -> bool:
        if not self.reason.strip():
            return False  # a noqa without a reason is not a suppression
        toks = {t.strip() for t in self.rules}
        return bool(toks & {finding.rule, finding.name, "all"})


class Module:
    """One parsed source file plus the per-line suppression table."""

    def __init__(self, path: str, source: str,
                 name: Optional[str] = None) -> None:
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.name = name if name is not None else module_name(path)
        self.is_package = os.path.basename(path) == "__init__.py"
        self.suppressions: Dict[int, Suppression] = {}
        for i, text in enumerate(self.lines, start=1):
            m = NOQA_RE.search(text)
            if m:
                rules = tuple(t.strip() for t in m.group("rules").split(","))
                self.suppressions[i] = Suppression(
                    rules, m.group("reason") or "", i)

    @property
    def package(self) -> str:
        """The package relative imports resolve against."""
        if self.is_package:
            return self.name
        return self.name.rsplit(".", 1)[0] if "." in self.name else ""


@dataclasses.dataclass
class Context:
    """Whole-program facts shared by every rule check."""

    modules: List[Module]
    reachable: Set[str]     # module names reachable from SERVICE_ROOTS

    def by_name(self) -> Dict[str, Module]:
        return {m.name: m for m in self.modules}


def module_name(path: str) -> str:
    """``.../src/repro/core/qr.py`` -> ``repro.core.qr`` (``__init__`` maps
    to its package).  Files outside a ``repro`` tree keep their stem."""
    parts = os.path.normpath(path).split(os.sep)
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    else:
        parts = parts[-1:]
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or os.path.basename(path)


def collect_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(
                d for d in dirnames
                if not d.startswith(".") and d != "__pycache__")
            for f in sorted(filenames):
                if f.endswith(".py"):
                    out.append(os.path.join(dirpath, f))
    return out


def load_modules(paths: Sequence[str]) -> List[Module]:
    mods = []
    for path in collect_py_files(paths):
        with open(path, encoding="utf-8") as f:
            mods.append(Module(path, f.read()))
    return mods


# ---------------------------------------------------------------------------
# Import graph / service reachability
# ---------------------------------------------------------------------------

def resolve_import_from(node: ast.ImportFrom, package: str) -> str:
    """Absolute dotted base of a ``from X import ...`` (handles relative)."""
    if node.level == 0:
        return node.module or ""
    parts = package.split(".") if package else []
    anchor = parts[:len(parts) - (node.level - 1)] if node.level - 1 else parts
    base = ".".join(anchor)
    if node.module:
        base = f"{base}.{node.module}" if base else node.module
    return base


def module_imports(mod: Module) -> Set[str]:
    """Every ``repro.*`` module this file imports, at ANY nesting depth
    (the lazy in-function import convention makes depth-0-only graphs
    blind to most of the execution path)."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            base = resolve_import_from(node, mod.package)
            if base:
                out.add(base)
                for alias in node.names:
                    out.add(f"{base}.{alias.name}")
    return {i for i in out if i == "repro" or i.startswith("repro.")}


def service_reachable(modules: Iterable[Module],
                      roots: Sequence[str] = SERVICE_ROOTS) -> Set[str]:
    """Modules reachable from the service workers through the import graph.

    Importing ``repro.a.b`` also reaches package ``repro.a`` (its
    ``__init__`` runs), so package ancestors join the frontier."""
    by_name = {m.name: m for m in modules}
    seen: Set[str] = set()
    frontier = [r for r in roots if r in by_name]
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for imp in module_imports(by_name[name]):
            parts = imp.split(".")
            for i in range(1, len(parts) + 1):
                cand = ".".join(parts[:i])
                if cand in by_name and cand not in seen:
                    frontier.append(cand)
    return seen


# ---------------------------------------------------------------------------
# Lint drivers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LintReport:
    findings: List[Finding]
    suppressed: List[Tuple[Finding, Suppression]]
    files: int
    unused_noqa: List[Tuple[str, Suppression]]  # (path, suppression)

    @property
    def ok(self) -> bool:
        return not self.findings


def lint_modules(modules: List[Module], rules=None,
                 roots: Sequence[str] = SERVICE_ROOTS) -> LintReport:
    from repro.analysis import rules as rules_mod

    active = tuple(rules) if rules is not None else rules_mod.RULES
    ctx = Context(modules=modules, reachable=service_reachable(modules, roots))
    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, Suppression]] = []
    used: Set[Tuple[str, int]] = set()
    for mod in modules:
        for rule in active:
            for finding in rule.check(mod, ctx):
                sup = mod.suppressions.get(finding.line)
                if sup is not None and sup.covers(finding):
                    suppressed.append((finding, sup))
                    used.add((mod.path, sup.line))
                else:
                    kept.append(finding)
    unused = [
        (mod.path, sup) for mod in modules
        for line, sup in sorted(mod.suppressions.items())
        if (mod.path, line) not in used
    ]
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintReport(kept, suppressed, len(modules), unused)


def lint_paths(paths: Sequence[str], rules=None,
               roots: Sequence[str] = SERVICE_ROOTS) -> LintReport:
    return lint_modules(load_modules(paths), rules=rules, roots=roots)


def lint_source(source: str, *, path: str = "<memory>",
                name: str = "repro.virtual", rules=None,
                reachable: bool = True) -> LintReport:
    """Lint one in-memory source (tests' negative fixtures).  With
    ``reachable=True`` the virtual module is treated as service-reachable so
    RL002 applies without building an import chain."""
    mod = Module(path, source, name=name)
    roots: Sequence[str] = (name,) if reachable else ()
    return lint_modules([mod], rules=rules, roots=roots)
