"""Jaxpr/HLO contract checker: machine-checked traffic/tracing invariants.

Layer 2 of `repro.analysis` (DESIGN.md §Static analysis).  Given any
`ExecutionPlan`, the checker abstractly traces the solve the plan's path
actually executes (ShapeDtypeStruct inputs — nothing is allocated except
the deliberately tiny concrete re-trace probe for batched plans) and
asserts the contracts the roofline model prices:

  C1 peak-intermediate   no materialized intermediate may reach m x n bytes
                         on matfree/sparse paths (and never exceed the
                         input residency on dense/batched/sharded); streamed
                         plans are checked statically — the plan's device
                         working set (staging panels + sketch-width state)
                         must undercut dense residency.
  C2 donation            the per-panel update steps (`blocked._add_donated`,
                         `_accum_xty`, `_gram_accum`, `adaptive._deflate_step`)
                         really alias their accumulator buffer in compiled
                         HLO — alias bytes == accumulator bytes, exactly.
  C3 row-panel-fallback  the generic `LinOp.row_panels` fallback (offset-
                         diagonal basis slices) lowers with NO gather /
                         scatter primitives.
  C4 reads-of-A          the number of A-touching contractions in the traced
                         jaxpr equals the pass count `rsvd_model` charges
                         for: 1+q fused, 2+2q unfused (sparse: SpMM count).
  C5 trace-accounting    a second identical batched solve must not re-trace
                         (`blocked._TRACE_COUNTS` moves by at most one per
                         plan, then stays put).

Tracing is tag-based: each traced input is tagged, view primitives
(transpose/reshape/...) propagate tags, and everything untagged that an
equation produces counts as a materialized intermediate.  `A.T` therefore
does not count as an m x n intermediate (XLA folds the transpose into
dot_general dimension numbers), while an actual densified copy does.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: primitives that constitute a "read" of their operands for traffic
#: accounting (a GEMM, a fused Pallas kernel, a BCOO SpMM).
MATMUL_PRIMS = {"dot_general", "pallas_call", "bcoo_dot_general"}
#: size-preserving relabelings of an existing buffer — tag-transparent.
VIEW_PRIMS = {"transpose", "reshape", "squeeze", "expand_dims", "rev"}
#: layout staging: `pad` to the Pallas tile quantum produces "A in padded
#: layout" — its reads are charged to the operand, and the staged copy is
#: input residency, not a derived intermediate (first-operand tag flows).
STAGING_PRIMS = {"pad"}
#: call-like primitives whose sub-jaxpr invars match the eqn invars
#: positionally, letting tags flow through.
CALL_PRIMS = {"pjit", "custom_jvp_call", "custom_vjp_call", "closed_call",
              "remat2", "checkpoint", "shard_map", "custom_vjp_call_jaxpr"}


class ContractViolation(AssertionError):
    """One or more plan contracts failed; `.results` carries the details."""

    def __init__(self, results: List["ContractResult"]):
        self.results = results
        bad = [r for r in results if not r.ok]
        super().__init__(
            "; ".join(f"{r.contract}[{r.plan_label}]: {r.detail}" for r in bad))


@dataclasses.dataclass(frozen=True)
class ContractResult:
    contract: str
    plan_label: str
    ok: bool
    detail: str


@dataclasses.dataclass(frozen=True)
class JaxprFacts:
    """What the tag-propagating jaxpr walk measured."""

    peak_intermediate_bytes: int
    reads: Dict[str, int]          # tag -> A-touching contraction count
    prim_counts: Dict[str, int]    # primitive name -> occurrences (recursive)

    def count(self, prim: str) -> int:
        return self.prim_counts.get(prim, 0)


# ---------------------------------------------------------------------------
# Tag-propagating jaxpr analysis
# ---------------------------------------------------------------------------

def _open_jaxpr(obj):
    """Duck-typed: ClosedJaxpr -> .jaxpr, open Jaxpr -> itself, else None.
    (shard_map carries an *open* jaxpr param; pjit a ClosedJaxpr.)"""
    if hasattr(obj, "jaxpr") and hasattr(obj, "consts"):
        return obj.jaxpr
    if hasattr(obj, "eqns") and hasattr(obj, "invars"):
        return obj
    return None


def _is_var(atom) -> bool:
    return not hasattr(atom, "val")  # Literals carry .val, Vars do not


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    size = 1
    for d in shape:
        size *= int(d)
    return size * jnp.dtype(dtype).itemsize


def _sub_jaxprs(eqn) -> List:
    subs = []
    for value in eqn.params.values():
        opened = _open_jaxpr(value)
        if opened is not None:
            subs.append(opened)
        elif isinstance(value, (tuple, list)):
            subs.extend(o for o in (_open_jaxpr(v) for v in value)
                        if o is not None)
    return subs


def _analyze(jaxpr, in_tags: Sequence[frozenset], facts: dict) -> List[frozenset]:
    """Walk one (open) jaxpr, threading input tags; returns outvar tags.

    `facts` accumulates {"peak": int, "reads": Counter-ish, "prims": dict}.
    """
    tags: Dict[object, frozenset] = {}
    for var, tag in zip(jaxpr.invars, in_tags):
        tags[var] = tag
    for cv in jaxpr.constvars:
        facts["peak"] = max(facts["peak"], _aval_bytes(cv.aval))
    empty = frozenset()

    def tag_of(atom) -> frozenset:
        return tags.get(atom, empty) if _is_var(atom) else empty

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        facts["prims"][name] = facts["prims"].get(name, 0) + 1
        eqn_in_tags = [tag_of(v) for v in eqn.invars]
        union: frozenset = empty
        for t in eqn_in_tags:
            union = union | t
        if name in MATMUL_PRIMS:
            for t in union:
                facts["reads"][t] = facts["reads"].get(t, 0) + 1
        out_tags: Optional[List[frozenset]] = None
        if name != "pallas_call":  # pallas params hold block-level jaxprs
            for sub in _sub_jaxprs(eqn):
                if name in CALL_PRIMS and len(sub.invars) == len(eqn.invars):
                    sub_out = _analyze(sub, eqn_in_tags, facts)
                    if len(sub_out) == len(eqn.outvars):
                        out_tags = sub_out
                else:
                    _analyze(sub, [empty] * len(sub.invars), facts)
        if out_tags is None:
            view = ((name in VIEW_PRIMS and len(eqn.invars) == 1)
                    or name in STAGING_PRIMS)
            out_tags = [eqn_in_tags[0] if view else empty
                        for _ in eqn.outvars]
        for var, tag in zip(eqn.outvars, out_tags):
            tags[var] = tag
            if not tag:
                facts["peak"] = max(facts["peak"], _aval_bytes(var.aval))
    return [tag_of(v) for v in jaxpr.outvars]


def trace_facts(fn: Callable, args: Sequence,
                tag_positions: Dict[int, str]) -> JaxprFacts:
    """Abstractly trace fn(*args) and measure peak intermediates + per-tag
    contraction reads.  `tag_positions` maps argument index -> tag name
    (typically {0: "A"})."""
    closed = jax.make_jaxpr(fn)(*args)
    in_tags = [frozenset([tag_positions[i]]) if i in tag_positions
               else frozenset() for i in range(len(closed.jaxpr.invars))]
    facts = {"peak": 0, "reads": {}, "prims": {}}
    _analyze(closed.jaxpr, in_tags, facts)
    return JaxprFacts(facts["peak"], dict(facts["reads"]),
                      dict(facts["prims"]))


# ---------------------------------------------------------------------------
# Individual contract verifiers (negative tests drive these directly)
# ---------------------------------------------------------------------------

def verify_peak(facts: JaxprFacts, bound_bytes: int) -> Tuple[bool, str]:
    ok = facts.peak_intermediate_bytes <= bound_bytes
    return ok, (f"peak materialized intermediate "
                f"{facts.peak_intermediate_bytes}B vs bound {bound_bytes}B")


def verify_reads(facts: JaxprFacts, expected: int,
                 tag: str = "A") -> Tuple[bool, str]:
    got = facts.reads.get(tag, 0)
    return got == expected, f"reads of {tag}: traced {got}, model says {expected}"


def verify_sparse_reads(facts: JaxprFacts, expected: int) -> Tuple[bool, str]:
    """Sparse transposition re-packs data/indices, which legitimately drops
    the tag — every BCOO contraction in a sparse solve IS a read of A, so
    the primitive count is the honest tally."""
    got = facts.count("bcoo_dot_general")
    return got == expected, (f"SpMM reads of A: traced {got} "
                             f"bcoo_dot_general, model says {expected}")


def verify_donation(jitted, args, acc_bytes: int, **kwargs) -> Tuple[bool, str]:
    compiled = jitted.lower(*args, **kwargs).compile()
    alias = compiled.memory_analysis().alias_size_in_bytes
    return alias == acc_bytes, (f"aliased {alias}B, accumulator is "
                                f"{acc_bytes}B (must match exactly)")


def verify_no_gather_scatter(fn: Callable, args: Sequence) -> Tuple[bool, str]:
    facts = trace_facts(fn, args, {})
    bad = sorted(p for p in facts.prim_counts
                 if "gather" in p or "scatter" in p)
    return not bad, (f"gather/scatter primitives in panel fallback: {bad}"
                     if bad else "no gather/scatter primitives")


def verify_no_retrace(solve: Callable, count: Callable[[], int]) -> Tuple[bool, str]:
    """Run `solve` twice; the trace tally may move at most once on the first
    call and must not move on the second."""
    before = count()
    solve()
    first = count() - before
    solve()
    second = count() - before - first
    ok = first <= 1 and second == 0
    return ok, (f"trace delta first call {first}, second call {second} "
                "(must be <=1 then 0)")


# ---------------------------------------------------------------------------
# Plan-level checks
# ---------------------------------------------------------------------------

def expected_reads_of_a(pl) -> int:
    """`rsvd_model` pass counts: 1+q with the fused power step, else 2+2q
    (sketch + two per stabilized/plain iteration + projection)."""
    q = int(pl.power_iters)
    return (1 + q) if pl.fused_power else (2 + 2 * q)


def intermediate_bound_bytes(pl) -> int:
    """C1 bound.  Matrix-free/sparse paths must stay strictly below ever
    materializing A; in-core paths must never exceed input residency."""
    itemsize = jnp.dtype(pl.dtype).itemsize
    mn = int(pl.m) * int(pl.n) * itemsize
    if pl.path in ("matfree", "sparse"):
        return mn - 1
    if pl.path == "batched":
        return int(pl.batch) * mn
    return mn


def streamed_working_set_bytes(pl) -> int:
    """Device residency of a streamed plan: staged panels (pipeline depth of
    them) plus the sketch-width state (Y m x s, Z/B n x s, Gram s x s)."""
    itemsize = jnp.dtype(pl.dtype).itemsize
    depth = max(1, int(pl.pipeline_depth or 1))
    panels = depth * int(pl.block_rows) * int(pl.n) * itemsize
    state = (int(pl.m) * int(pl.s) + 2 * int(pl.n) * int(pl.s)
             + 2 * int(pl.s) * int(pl.s)) * itemsize
    return panels + state


def _seed_sds():
    return jax.ShapeDtypeStruct((), jnp.uint32)


def _guard_wrap(pl, body: Callable) -> Callable:
    """Under guard report/retry the body traces with an open probe sink —
    the contract run must mirror that (probes ride the same trace)."""
    if pl.guard is None or pl.guard.mode == "off":
        return body

    def wrapped(*args):
        from repro.linalg import guard as guard_mod

        with guard_mod.collecting():
            return body(*args)

    return wrapped


def _traceable_for(pl, op=None):
    """(fn, args, tag_positions) abstractly tracing what the plan executes,
    or None for paths checked statically (streamed/adaptive)."""
    from repro.core import blocked, qr as qr_mod, rsvd

    dtype = jnp.dtype(pl.dtype)
    m, n, k = int(pl.m), int(pl.n), int(pl.k)
    cfg = pl.to_config()
    if pl.path == "dense":
        def body(A, seed):
            with qr_mod.kernel_backend(cfg.kernel_backend):
                return rsvd._rsvd_body(A, k, cfg, seed)

        return (_guard_wrap(pl, body),
                (jax.ShapeDtypeStruct((m, n), dtype), _seed_sds()), {0: "A"})
    if pl.path == "batched":
        bcfg = blocked.batched_cfg(cfg)

        def body(stack, seeds):
            return blocked._batched_tall_body(stack, seeds, k, bcfg)

        return (_guard_wrap(pl, body),
                (jax.ShapeDtypeStruct((int(pl.batch), m, n), dtype),
                 jax.ShapeDtypeStruct((int(pl.batch),), jnp.uint32)),
                {0: "A"})
    if pl.path == "matfree":
        from repro.linalg import api as api_mod
        from repro.linalg import pipeline as pipeline_mod
        from repro.linalg.operators import CenteredOp, DenseOp

        def body(X, mu, seed):
            with pipeline_mod.default_depth(pl.pipeline_depth):
                return api_mod._matfree_svd(
                    CenteredOp(DenseOp(X), mu), k, pl, seed)

        return (_guard_wrap(pl, body),
                (jax.ShapeDtypeStruct((m, n), dtype),
                 jax.ShapeDtypeStruct((n,), dtype), _seed_sds()), {0: "A"})
    if pl.path == "sparse":
        from jax.experimental import sparse as jsparse

        from repro.linalg import api as api_mod
        from repro.linalg import pipeline as pipeline_mod
        from repro.linalg.operators import SparseOp

        bcoo = op.bcoo if op is not None else _synthetic_bcoo(m, n, dtype)

        def body(data, seed):
            a = jsparse.BCOO((data, bcoo.indices), shape=bcoo.shape)
            with pipeline_mod.default_depth(pl.pipeline_depth):
                return api_mod._matfree_svd(SparseOp(a), k, pl, seed)

        return (_guard_wrap(pl, body),
                (jax.ShapeDtypeStruct(bcoo.data.shape, dtype), _seed_sds()),
                {0: "A"})
    if pl.path == "sharded":
        from repro.core import distributed

        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))

        def body(A):
            return distributed.svd_sharded(A, k, mesh, "data", cfg, seed=0)

        return (_guard_wrap(pl, body),
                (jax.ShapeDtypeStruct((m, n), dtype),), {0: "A"})
    return None


def _synthetic_bcoo(m, n, dtype):
    from jax.experimental import sparse as jsparse

    mask = (np.arange(m * n) % 11 == 0).reshape(m, n)
    dense = np.where(mask, 1.0, 0.0).astype(np.dtype(dtype))
    return jsparse.BCOO.fromdense(jnp.asarray(dense))


def _matmat_only_op(X):
    """A protocol-only LinOp (no .array): exercises the generic row_panels
    basis-slice fallback, the codepath C3 pins gather/scatter-free."""
    from repro.linalg.operators import LinOp

    class _MatmatOnly(LinOp):
        @property
        def shape(self):
            return tuple(X.shape)

        @property
        def dtype(self):
            return X.dtype

        def matmat(self, B):
            return X @ B

        def rmatmat(self, Y):
            return X.T @ Y

    return _MatmatOnly()


def _check_donation_suite(pl, label: str) -> List[ContractResult]:
    from repro.core import adaptive, blocked

    dtype = jnp.dtype(pl.dtype)
    s = max(2, int(pl.s))
    b = max(2, min(int(pl.block_rows or 8), 8))
    n = min(int(pl.n), 16)
    m = min(int(pl.m), 32)
    acc = jax.ShapeDtypeStruct((n, s), dtype)
    results = []
    cases = [
        ("blocked._add_donated",
         lambda: verify_donation(blocked._add_donated,
                                 (acc, jax.ShapeDtypeStruct((n, s), dtype)),
                                 n * s * dtype.itemsize)),
        ("blocked._accum_xty",
         lambda: verify_donation(blocked._accum_xty,
                                 (acc, jax.ShapeDtypeStruct((b, n), dtype),
                                  jax.ShapeDtypeStruct((b, s), dtype)),
                                 n * s * dtype.itemsize)),
        ("blocked._gram_accum",
         lambda: verify_donation(blocked._gram_accum,
                                 (jax.ShapeDtypeStruct((s, s), dtype),
                                  jax.ShapeDtypeStruct((b, s), dtype)),
                                 s * s * dtype.itemsize, backend="jnp")),
        ("adaptive._deflate_step",
         lambda: verify_donation(adaptive._deflate_step,
                                 (jax.ShapeDtypeStruct((m, b), dtype),
                                  jax.ShapeDtypeStruct((m, s), dtype)),
                                 m * b * dtype.itemsize)),
    ]
    for name, run in cases:
        ok, detail = run()
        results.append(ContractResult("C2-donation", label, ok,
                                      f"{name}: {detail}"))
    return results


def check_plan_contracts(pl, label: Optional[str] = None,
                         op=None) -> List[ContractResult]:
    """Every contract applicable to this plan's path, as a result list."""
    label = label or f"{pl.path}:{pl.m}x{pl.n}:k{pl.k}:guard-{pl.guard.mode}"
    results: List[ContractResult] = []

    traceable = _traceable_for(pl, op=op)
    if traceable is not None:
        fn, args, tag_positions = traceable
        facts = trace_facts(fn, args, tag_positions)
        ok, detail = verify_peak(facts, intermediate_bound_bytes(pl))
        results.append(ContractResult("C1-peak-intermediate", label, ok, detail))
        if pl.path == "sparse":
            ok, detail = verify_sparse_reads(facts, expected_reads_of_a(pl))
        else:
            ok, detail = verify_reads(facts, expected_reads_of_a(pl))
        results.append(ContractResult("C4-reads-of-a", label, ok, detail))

    if pl.path in ("streamed", "adaptive"):
        ws = streamed_working_set_bytes(pl) if pl.path == "streamed" else None
        if ws is not None:
            dense_bytes = int(pl.m) * int(pl.n) * jnp.dtype(pl.dtype).itemsize
            results.append(ContractResult(
                "C1-peak-intermediate", label, ws < dense_bytes,
                f"streamed device working set {ws}B vs dense residency "
                f"{dense_bytes}B (streaming must undercut it)"))
        results.extend(_check_donation_suite(pl, label))

    if pl.path in ("matfree", "sparse"):
        dtype = jnp.dtype(pl.dtype)
        block = max(2, min(int(pl.m), 8))

        def one_panel(X):
            oper = _matmat_only_op(X)
            for panel in oper.row_panels(block):
                return panel

        ok, detail = verify_no_gather_scatter(
            one_panel,
            (jax.ShapeDtypeStruct((min(int(pl.m), 32), min(int(pl.n), 16)),
                                  dtype),))
        results.append(ContractResult("C3-row-panel-fallback", label, ok,
                                      detail))

    if pl.path == "batched":
        results.append(_check_trace_accounting(pl, label))
    return results


def _check_trace_accounting(pl, label: str) -> ContractResult:
    from repro.core import blocked
    from repro.serve.decomp import cache as serve_cache

    dtype = jnp.dtype(pl.dtype)
    batch, m, n, k = int(pl.batch), int(pl.m), int(pl.n), int(pl.k)
    cfg = pl.to_config()
    # Deterministic filler (counter-RNG-free on purpose): conditioning is
    # irrelevant here, only whether the program re-traces.
    stack = ((jnp.arange(batch * m * n, dtype=jnp.float32)
              .reshape(batch, m, n) * 0.37) % 1.0 + 0.1).astype(dtype)
    seeds = blocked.slice_seeds(0, batch)

    def solve():
        jax.block_until_ready(blocked.svd_batched(stack, k, cfg, seed=seeds))

    ok, detail = verify_no_retrace(solve, lambda: serve_cache.trace_count(pl))
    return ContractResult("C5-trace-accounting", label, ok, detail)


def assert_plan_contracts(pl, label: Optional[str] = None,
                          op=None) -> List[ContractResult]:
    """Pytest-facing entry: raises ContractViolation on any failed contract,
    returns the full result list otherwise."""
    results = check_plan_contracts(pl, label=label, op=op)
    if any(not r.ok for r in results):
        raise ContractViolation(results)
    return results


# ---------------------------------------------------------------------------
# Golden dispatch-table sweep
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepReport:
    plans: List[str]
    results: List[ContractResult]

    @property
    def violations(self) -> List[ContractResult]:
        return [r for r in self.results if not r.ok]

    @property
    def ok(self) -> bool:
        return not self.violations


def golden_plan_table() -> List[Tuple[str, object, object]]:
    """(label, plan, op) across every planner path x guard off/report —
    small shapes (plans are shape-only; tracing allocates nothing)."""
    from repro import linalg
    from repro.core.rsvd import RSVDConfig
    from repro.linalg.operators import SparseOp

    def sds(m, n, dt=jnp.float32):
        return jax.ShapeDtypeStruct((m, n), dt)

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    bcoo = _synthetic_bcoo(64, 32, jnp.float32)
    entries = []
    for guard in (None, "report"):
        tag = "off" if guard is None else guard
        cases = [
            (f"dense_faithful_{tag}",
             lambda: linalg.plan(linalg.DenseOp(sds(96, 48)), 8,
                                 guard=guard), None),
            (f"dense_fast_{tag}",
             lambda: linalg.plan(linalg.DenseOp(sds(96, 48)), 8,
                                 overrides=RSVDConfig.fast(), guard=guard),
             None),
            (f"dense_f64_{tag}",
             lambda: linalg.plan(linalg.DenseOp(sds(64, 32, jnp.float64)), 6,
                                 guard=guard), None),
            (f"wide_orientation_{tag}",
             lambda: linalg.plan(linalg.DenseOp(sds(32, 96)), 6,
                                 guard=guard), None),
            (f"streamed_{tag}",
             lambda: linalg.plan(linalg.DenseOp(sds(4096, 128)), 8,
                                 overrides=RSVDConfig.streaming(1024),
                                 guard=guard), None),
            (f"batched_{tag}",
             lambda: linalg.plan(linalg.StackedOp(jnp.zeros((3, 48, 24))), 4,
                                 overrides=RSVDConfig.fast(), guard=guard),
             None),
            (f"sharded_{tag}",
             lambda: linalg.plan(linalg.ShardedOp(sds(128, 32), mesh, "data"),
                                 8, guard=guard), None),
            (f"matfree_{tag}",
             lambda: linalg.plan(
                 linalg.CenteredOp(linalg.DenseOp(sds(96, 48))), 8,
                 guard=guard), None),
            (f"sparse_{tag}",
             lambda: linalg.plan(SparseOp(bcoo), 4, guard=guard),
             SparseOp(bcoo)),
            (f"adaptive_{tag}",
             lambda: linalg.plan(linalg.DenseOp(sds(96, 48)),
                                 linalg.Tolerance(1e-2), guard=guard), None),
        ]
        for label, mk_plan, op in cases:
            entries.append((label, mk_plan(), op))
    return entries


def sweep(entries=None) -> SweepReport:
    """Run every contract over the golden dispatch table (the CLI's
    `--contracts` mode and the CI analysis lane)."""
    entries = golden_plan_table() if entries is None else entries
    results: List[ContractResult] = []
    labels = []
    for label, pl, op in entries:
        labels.append(label)
        results.extend(check_plan_contracts(pl, label=label, op=op))
    return SweepReport(labels, results)


__all__ = [
    "ContractResult", "ContractViolation", "JaxprFacts", "SweepReport",
    "assert_plan_contracts", "check_plan_contracts", "expected_reads_of_a",
    "golden_plan_table", "intermediate_bound_bytes",
    "streamed_working_set_bytes", "sweep", "trace_facts", "verify_donation",
    "verify_no_gather_scatter", "verify_no_retrace", "verify_peak",
    "verify_reads", "verify_sparse_reads",
]
