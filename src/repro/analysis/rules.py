"""The repo-specific lint rules (catalog in DESIGN.md §Static analysis).

Each rule encodes an invariant the paper's cost model or the PR 8 threading
model depends on, previously defended only by convention or by one
hand-written test:

  RL001 core-layering       repro.core never imports repro.linalg at module
                            level (the sys.modules / lazy-import convention,
                            made mechanical).
  RL002 mutable-global      no mutated module-level dict/list/set/Counter in
                            any module reachable from the service workers
                            unless every mutation site is inside a ``with``
                            on a module-level threading lock (threading.local
                            state never triggers it; allowlist via noqa with
                            a stated reason).
  RL003 unfrozen-key        dataclasses that key jit caches / the executable
                            cache / coalescing buckets must be frozen with
                            hashable field annotations.
  RL004 host-rng            no numpy.random / stdlib random in src/ — the
                            counter RNG (seed-as-data) is the only sanctioned
                            randomness, so compiled programs stay seed-sweep
                            reusable and bit-reproducible.
  RL005 bare-except         no ``except:`` — it swallows KeyboardInterrupt in
                            worker loops and masks guard escalations.
  RL006 dense-lapack        no jnp.linalg.{svd,qr,eigh} outside core/qr.py
                            and the registered finishers — full-size LAPACK
                            factorizations are exactly what the paper's
                            formulation avoids; sketch-width uses must carry
                            a noqa stating why the operand is small.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.analysis.engine import Context, Finding, Module

MUTABLE_CONTAINER_CALLS = {
    "dict", "list", "set", "Counter", "OrderedDict", "defaultdict", "deque",
}
LOCK_CALLS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
THREAD_LOCAL_CALLS = {"local"}
MUTATING_METHODS = {
    "append", "appendleft", "add", "clear", "update", "setdefault", "pop",
    "popitem", "extend", "insert", "remove", "discard", "move_to_end",
}
#: dataclasses that key a cache somewhere (jit static args, the executable
#: cache, the LRU plan cache, coalescing buckets, the autotune table, fault
#: fingerprints) — must be frozen, with hashable field annotations.
KEY_DATACLASSES = {
    "ExecutionPlan", "Budget",                      # linalg/planner.py
    "Spec", "Rank", "Tolerance", "Energy",          # linalg/spec.py
    "GuardPolicy",                                  # linalg/guard.py
    "RSVDConfig",                                   # core/rsvd.py
    "CoalesceKey",                                  # serve/decomp/coalesce.py
    "BlockSizes",                                   # kernels/autotune.py
    "Fault",                                        # linalg/faults.py
    "SnapshotRef",                                  # linalg/snapshot.py
    "JobRecord",                                    # serve/decomp/jobstore.py
}
UNHASHABLE_ANNOTATIONS = {
    "list", "dict", "set", "List", "Dict", "Set", "MutableMapping",
    "MutableSequence", "bytearray", "ndarray", "Array",
}
DENSE_LAPACK_FUNCS = {"svd", "qr", "eigh"}
#: whole modules where dense LAPACK calls are the point.
DENSE_LAPACK_ALLOWED_MODULES = {"repro.core.qr"}


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    doc: str
    check: Callable[[Module, Context], List[Finding]]


def _f(rule: "Rule", mod: Module, node: ast.AST, message: str) -> Finding:
    return Finding(rule.id, rule.name, mod.path,
                   getattr(node, "lineno", 1), message)


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(node: ast.expr) -> Optional[str]:
    """Last component of the callee of a Call ('threading.Lock' -> 'Lock')."""
    if not isinstance(node, ast.Call):
        return None
    d = _dotted(node.func)
    return d.rsplit(".", 1)[-1] if d else None


# ---------------------------------------------------------------------------
# RL001 — core must not import linalg at module level
# ---------------------------------------------------------------------------

def _check_core_layering(mod: Module, ctx: Context) -> List[Finding]:
    if not (mod.name == "repro.core" or mod.name.startswith("repro.core.")):
        return []
    findings: List[Finding] = []

    def walk(node: ast.AST, in_function: bool) -> None:
        for child in ast.iter_child_nodes(node):
            is_fn = isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                       ast.Lambda))
            if not in_function:
                target = None
                if isinstance(child, ast.Import):
                    for alias in child.names:
                        if alias.name.startswith("repro.linalg"):
                            target = alias.name
                elif isinstance(child, ast.ImportFrom):
                    from repro.analysis.engine import resolve_import_from
                    base = resolve_import_from(child, mod.package)
                    if base.startswith("repro.linalg"):
                        target = base
                if target is not None:
                    findings.append(_f(CORE_LAYERING, mod, child,
                                       f"module-level import of {target!r}: "
                                       "repro.core must reach repro.linalg "
                                       "only lazily (sys.modules probe or "
                                       "in-function import)"))
            walk(child, in_function or is_fn)

    walk(mod.tree, False)
    return findings


# ---------------------------------------------------------------------------
# RL002 — mutated module-level containers in service-reachable modules
# ---------------------------------------------------------------------------

def _module_globals(mod: Module) -> Tuple[Dict[str, int], Set[str]]:
    """(mutable container globals -> def line, module-level lock names)."""
    containers: Dict[str, int] = {}
    locks: Set[str] = set()
    for stmt in mod.tree.body:
        target = None
        value = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name):
            target, value = stmt.targets[0].id, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name) and stmt.value is not None:
            target, value = stmt.target.id, stmt.value
        if target is None:
            continue
        callee = _call_name(value)
        if callee in LOCK_CALLS:
            locks.add(target)
        elif callee in THREAD_LOCAL_CALLS:
            continue  # threading.local() is the sanctioned per-thread state
        elif isinstance(value, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                                ast.SetComp, ast.DictComp)) or \
                callee in MUTABLE_CONTAINER_CALLS:
            containers[target] = stmt.lineno
    return containers, locks


def _check_mutable_global(mod: Module, ctx: Context) -> List[Finding]:
    if mod.name not in ctx.reachable:
        return []
    containers, locks = _module_globals(mod)
    if not containers:
        return []
    # name -> list of (lineno, guarded) mutation sites inside functions
    sites: Dict[str, List[Tuple[int, bool]]] = {n: [] for n in containers}

    def is_locked_with(stmt: ast.With) -> bool:
        for item in stmt.items:
            expr = item.context_expr
            name = None
            if isinstance(expr, ast.Name):
                name = expr.id
            elif isinstance(expr, ast.Call):
                d = _dotted(expr.func)
                name = d.split(".", 1)[0] if d else None
            if name in locks:
                return True
        return False

    def record(name: Optional[str], node: ast.AST, lock_depth: int) -> None:
        if name in sites:
            sites[name].append((node.lineno, lock_depth > 0))

    def sub_name(target: ast.expr) -> Optional[str]:
        if isinstance(target, ast.Subscript) and \
                isinstance(target.value, ast.Name):
            return target.value.id
        return None

    def walk(node: ast.AST, in_function: bool, lock_depth: int,
             declared_global: Set[str]) -> None:
        for child in ast.iter_child_nodes(node):
            fn = isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef))
            child_globals = set() if fn else declared_global
            child_locks = lock_depth
            if isinstance(child, ast.With) and is_locked_with(child):
                child_locks += 1
            if in_function:
                if isinstance(child, ast.Global):
                    declared_global.update(child.names)
                elif isinstance(child, ast.Assign):
                    for t in child.targets:
                        record(sub_name(t), child, lock_depth)
                        if isinstance(t, ast.Name) and t.id in declared_global:
                            record(t.id, child, lock_depth)
                elif isinstance(child, ast.AugAssign):
                    record(sub_name(child.target), child, lock_depth)
                    if isinstance(child.target, ast.Name) and \
                            child.target.id in declared_global:
                        record(child.target.id, child, lock_depth)
                elif isinstance(child, ast.Delete):
                    for t in child.targets:
                        record(sub_name(t), child, lock_depth)
                elif isinstance(child, ast.Call) and \
                        isinstance(child.func, ast.Attribute) and \
                        child.func.attr in MUTATING_METHODS and \
                        isinstance(child.func.value, ast.Name):
                    record(child.func.value.id, child, lock_depth)
            walk(child, in_function or fn, child_locks, child_globals)

    walk(mod.tree, False, 0, set())
    findings: List[Finding] = []
    for name, def_line in sorted(containers.items(), key=lambda kv: kv[1]):
        mutated = sites[name]
        unguarded = [line for line, guarded in mutated if not guarded]
        if mutated and unguarded:
            findings.append(Finding(
                MUTABLE_GLOBAL.id, MUTABLE_GLOBAL.name, mod.path, def_line,
                f"module-level mutable global {name!r} in a service-reachable"
                f" module is mutated without a module lock (line"
                f" {unguarded[0]}); use threading.local, hold a module-level"
                " threading lock at every mutation site, or noqa with a"
                " reason"))
    return findings


# ---------------------------------------------------------------------------
# RL003 — plan/cache-key dataclasses: frozen, hashable fields
# ---------------------------------------------------------------------------

def _annotation_unhashable(ann: ast.AST) -> Optional[str]:
    for node in ast.walk(ann):
        label = None
        if isinstance(node, ast.Name):
            label = node.id
        elif isinstance(node, ast.Attribute):
            label = node.attr
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            try:  # string annotations ("jax.Array") — parse and re-check
                label_node = ast.parse(node.value, mode="eval").body
            except SyntaxError:
                continue
            inner = _annotation_unhashable(label_node)
            if inner:
                return inner
        if label in UNHASHABLE_ANNOTATIONS:
            return label
    return None


def _dataclass_frozen(cls: ast.ClassDef) -> Optional[bool]:
    """True/False if decorated with @dataclass(...), None if not one."""
    for dec in cls.decorator_list:
        d = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
        if d and d.rsplit(".", 1)[-1] in ("dataclass",):
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "frozen":
                        return (isinstance(kw.value, ast.Constant)
                                and bool(kw.value.value))
            return False
    return None


def _check_frozen_keys(mod: Module, ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef) or \
                node.name not in KEY_DATACLASSES:
            continue
        frozen = _dataclass_frozen(node)
        if frozen is None:
            continue  # a non-dataclass homonym is out of scope
        if not frozen:
            findings.append(_f(FROZEN_KEYS, mod, node,
                               f"dataclass {node.name!r} keys a plan/jit/"
                               "coalesce cache and must be declared "
                               "@dataclass(frozen=True)"))
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and stmt.annotation is not None:
                bad = _annotation_unhashable(stmt.annotation)
                if bad:
                    findings.append(_f(
                        FROZEN_KEYS, mod, stmt,
                        f"key dataclass {node.name!r} field annotated with "
                        f"unhashable type {bad!r} — cache keys must hash"))
    return findings


# ---------------------------------------------------------------------------
# RL004 — no numpy.random / stdlib random
# ---------------------------------------------------------------------------

def _numpy_aliases(mod: Module) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in ("numpy", "numpy.random"):
                    out.add((alias.asname or alias.name).split(".", 1)[0])
    return out


def _check_host_rng(mod: Module, ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    aliases = _numpy_aliases(mod)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "random" or alias.name == "numpy.random":
                    findings.append(_f(HOST_RNG, mod, node,
                                       f"import of {alias.name!r}: only the "
                                       "counter RNG (seed-as-data) is allowed"
                                       " in src/"))
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level == 0 and base.split(".", 1)[0] == "random":
                findings.append(_f(HOST_RNG, mod, node,
                                   "import from stdlib 'random': only the "
                                   "counter RNG (seed-as-data) is allowed in "
                                   "src/"))
            elif node.level == 0 and base == "numpy.random":
                findings.append(_f(HOST_RNG, mod, node,
                                   "import from numpy.random: only the "
                                   "counter RNG (seed-as-data) is allowed in "
                                   "src/"))
        elif isinstance(node, ast.Attribute) and node.attr == "random" and \
                isinstance(node.value, ast.Name) and node.value.id in aliases:
            findings.append(_f(HOST_RNG, mod, node,
                               "numpy.random use: host RNG breaks seed-sweep "
                               "program reuse and cross-device "
                               "reproducibility (counter RNG only)"))
    return findings


# ---------------------------------------------------------------------------
# RL005 — no bare except
# ---------------------------------------------------------------------------

def _check_bare_except(mod: Module, ctx: Context) -> List[Finding]:
    return [
        _f(BARE_EXCEPT, mod, node,
           "bare 'except:' swallows KeyboardInterrupt/SystemExit in worker "
           "loops — name the exception")
        for node in ast.walk(mod.tree)
        if isinstance(node, ast.ExceptHandler) and node.type is None
    ]


# ---------------------------------------------------------------------------
# RL006 — dense LAPACK calls outside sanctioned sites
# ---------------------------------------------------------------------------

def _registered_finishers(mod: Module) -> Set[str]:
    """Function names passed to DecompositionKind(...) in this module —
    the statically-visible 'registered finisher' set."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d and d.rsplit(".", 1)[-1] == "DecompositionKind":
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        out.add(arg.id)
    return out


def _check_dense_lapack(mod: Module, ctx: Context) -> List[Finding]:
    if mod.name in DENSE_LAPACK_ALLOWED_MODULES:
        return []
    finishers = _registered_finishers(mod)
    findings: List[Finding] = []

    def walk(node: ast.AST, fn_stack: Tuple[str, ...]) -> None:
        for child in ast.iter_child_nodes(node):
            stack = fn_stack
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack = fn_stack + (child.name,)
            if isinstance(child, ast.Call):
                d = _dotted(child.func)
                if d:
                    parts = d.split(".")
                    if len(parts) >= 3 and parts[-2] == "linalg" and \
                            parts[-1] in DENSE_LAPACK_FUNCS and \
                            parts[0] in ("jnp", "np", "numpy", "jax", "scipy"):
                        if not any(f in finishers for f in stack):
                            findings.append(_f(
                                DENSE_LAPACK, mod, child,
                                f"{d}(...) outside core/qr.py and registered "
                                "finishers — the BLAS-3 formulation exists to"
                                " avoid full-size LAPACK factorizations; if "
                                "the operand is sketch-width, say so in a "
                                "noqa reason"))
            walk(child, stack)

    walk(mod.tree, ())
    return findings


CORE_LAYERING = Rule(
    "RL001", "core-layering",
    "repro.core must not import repro.linalg at module level",
    _check_core_layering)
MUTABLE_GLOBAL = Rule(
    "RL002", "mutable-global",
    "no unsynchronized module-level mutable state in service-reachable "
    "modules", _check_mutable_global)
FROZEN_KEYS = Rule(
    "RL003", "unfrozen-key",
    "plan/cache-key dataclasses must be frozen with hashable fields",
    _check_frozen_keys)
HOST_RNG = Rule(
    "RL004", "host-rng",
    "no numpy.random / stdlib random in src/ (counter RNG only)",
    _check_host_rng)
BARE_EXCEPT = Rule(
    "RL005", "bare-except", "no bare 'except:'", _check_bare_except)
DENSE_LAPACK = Rule(
    "RL006", "dense-lapack",
    "no jnp.linalg.{svd,qr,eigh} outside core/qr.py and registered "
    "finishers", _check_dense_lapack)

RULES: Tuple[Rule, ...] = (
    CORE_LAYERING, MUTABLE_GLOBAL, FROZEN_KEYS, HOST_RNG, BARE_EXCEPT,
    DENSE_LAPACK,
)
