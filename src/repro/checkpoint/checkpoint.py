"""Fault-tolerant sharded checkpointing (pure Python + numpy, no orbax).

Layout:  <dir>/step_<N>/
           manifest.json     — step, config fingerprint, mesh shape, leaf index
           shard_<host>.npz  — this host's leaf shards (flattened pytree)

Features required for 1000+-node deployment:
  * per-host shard files: each host writes only ITS bytes (here: single host
    writes everything, but the addressing scheme is per-shard);
  * async save: the serializing thread runs off the training loop; the loop
    only blocks if a previous save is still in flight (double-buffer rule);
  * atomic publish: write to step_<N>.tmp, fsync, rename — a crash mid-save
    can never corrupt the latest valid checkpoint;
  * keep-last-N garbage collection;
  * RESHARD-ON-LOAD: restore does not require the saving mesh — leaves are
    stored unsharded per-leaf (host gathers its shards), so an elastic
    restart onto a smaller/larger mesh just re-applies the new sharding.
    This is the elastic-scaling path (node loss -> restore on fewer hosts).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _fsync_dir(path) -> None:
    """fsync a directory (durability of renames published inside it).
    No-op on platforms that refuse to open directories."""
    try:
        fd = os.open(str(path), os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _fingerprint(tree) -> str:
    """Structure+shape+dtype fingerprint to reject incompatible restores."""
    parts = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keystr = jax.tree_util.keystr(path)
        parts.append(f"{keystr}:{getattr(leaf, 'shape', ())}:{getattr(leaf, 'dtype', '')}")
    import hashlib

    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ------------------------------------------------

    def save(self, step: int, tree: Params, *, blocking: bool = False, extra: Dict | None = None):
        """Snapshot `tree` at `step`. Device->host copy happens synchronously
        (correctness); serialization happens on a worker thread."""
        host_tree = jax.tree.map(lambda l: np.asarray(l), tree)
        self.wait()  # double-buffer: at most one save in flight
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra: Dict):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves, treedef = jax.tree.flatten(host_tree)
        with open(tmp / "shard_0.npz", "wb") as f:
            np.savez(f, **{f"leaf_{i}": l for i, l in enumerate(leaves)})
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "fingerprint": _fingerprint(host_tree),
            "time": time.time(),
            **extra,
        }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        # the rename lives in the PARENT directory's metadata: without a
        # directory fsync a power failure can roll the publish itself back
        # even though both payload files were synced
        _fsync_dir(self.dir)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore --------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        like: Params,
        step: Optional[int] = None,
        *,
        shardings: Optional[Params] = None,
    ) -> Tuple[Params, int]:
        """Restore into the structure of `like`; `shardings` (a congruent
        pytree of NamedSharding) applies the CURRENT mesh — reshard-on-load."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        fp = _fingerprint(like)
        if manifest["fingerprint"] != fp:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']} != model {fp} "
                "(architecture/config mismatch)"
            )
        data = np.load(d / "shard_0.npz")
        leaves_like, treedef = jax.tree.flatten(like)
        loaded = [
            np.asarray(data[f"leaf_{i}"]) for i in range(manifest["n_leaves"])
        ]
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrs = [
                jax.device_put(l, s) if s is not None else jnp.asarray(l)
                for l, s in zip(loaded, flat_sh)
            ]
        else:
            arrs = [jnp.asarray(l) for l in loaded]
        return jax.tree.unflatten(treedef, arrs), step
